"""Paper Figs. 8/9/10, Table 5, Fig. A3 analogues.

Hardware caveat (1 CPU core): wall-clock multi-worker speedups are not
measurable, so scaling figures report the *model* quantities the paper's
speedups derive from — per-partition work balance (compute bound),
master/mirror halo traffic (comm bound), and the mini-batch redundancy
factor that explains DistDGL's non-scaling (Fig. 9 / §5.3.2).
"""
from __future__ import annotations


import jax
import numpy as np

from benchmarks.common import emit, time_call
from repro.core.clustering import label_propagation_clusters
from repro.core.partition import build_partitions, partition_stats
from repro.core.strategies import (cluster_batch_views, global_batch_view,
                                   mini_batch_views, shard_view)
from repro.core.subgraph import khop_subgraph_view
from repro.graph import make_dataset, powerlaw_graph


def fig8_scaling():
    """Strong-scaling bounds for the hybrid-parallel engine on the
    alipay-like graph: speedup_bound(P) = total_work / max_partition_work;
    halo values per step (comm term)."""
    g = powerlaw_graph(num_nodes=20000, avg_degree=6, seed=0)
    base_work = None
    for P in (4, 8, 16, 32, 64):
        sg = build_partitions(g, P, method="1d_src")
        stats = partition_stats(sg)
        per_part_edges = sg.plan.edge_mask.sum(axis=1)
        work = float(per_part_edges.max())
        if base_work is None:
            base_work = float(per_part_edges.sum())
        speedup_bound = base_work / work
        emit(f"fig8/alipay_like/P{P}", 0.0,
             f"speedup_bound={speedup_bound:.2f};"
             f"halo_per_sync={stats['halo_values_per_sync']:.0f};"
             f"edge_balance={stats['edge_balance']:.3f}")


def fig9_redundancy():
    """Data-parallel mini-batch (DistDGL model): per-trainer subgraphs
    replicate shared neighbors, and total work GROWS with #trainers while
    the hybrid-parallel subgraph is trainer-count invariant."""
    g = make_dataset("reddit_like", num_nodes=4000, seed=0)
    rng = np.random.default_rng(0)
    labeled = np.where(g.train_mask)[0]
    batch = rng.choice(labeled, 512, replace=False)
    _, _, _, visited_full = khop_subgraph_view(g, batch, 2)
    full = int(visited_full.sum())
    for w in (1, 2, 4, 8, 16, 32):
        parts = np.array_split(batch, w)
        total = 0
        for part in parts:
            _, _, _, visited = khop_subgraph_view(g, part, 2)
            total += int(visited.sum())
        emit(f"fig9/reddit_like/trainers{w}", 0.0,
             f"redundancy_factor={total / full:.3f};"
             f"dp_total_nodes={total};hybrid_nodes={full}")


def table5_sampling_cost():
    """GraphLearn-style sampled neighborhoods vs full (the unfair-compute
    argument of §5.3.3): nodes/edges touched per batch at depths 2-4."""
    g = make_dataset("reddit_like", num_nodes=4000, seed=0)
    rng = np.random.default_rng(1)
    batch = rng.choice(np.where(g.train_mask)[0], 256, replace=False)
    settings = {"full": 0, "cap10": 10, "cap3": 3}
    for depth in (2, 3, 4):
        counts = {}
        for name, cap in settings.items():
            _, _, _, visited = khop_subgraph_view(
                g, batch, depth, neighbor_cap=cap,
                rng=np.random.default_rng(2))
            counts[name] = int(visited.sum())
        emit(f"table5/reddit_like/depth{depth}", 0.0,
             f"full={counts['full']};cap10={counts['cap10']};"
             f"cap3={counts['cap3']};"
             f"savings10={counts['full'] / max(counts['cap10'], 1):.2f}x")


def fig10_partitioning():
    """vertex-cut vs 1D-edge partition per training strategy (comm volume
    + peak memory proxies, §5.4)."""
    g = make_dataset("amazon_like", num_nodes=6000, seed=0)
    cl = label_propagation_clusters(g, max_cluster_size=600, iters=3,
                                    seed=0)
    views = {
        "global": global_batch_view(g, 2),
        "mini": next(mini_batch_views(g, 2, batch_nodes=60, seed=0)),
        "cluster": next(cluster_batch_views(g, 2, cl, 2, halo_hops=1,
                                            seed=0)),
    }
    for method in ("1d_src", "vertex_cut"):
        sg = build_partitions(g, 8, method=method)
        stats = partition_stats(sg)
        for sname, view in views.items():
            # active-weighted halo: only masters used by the view move
            active = (np.ones(g.num_nodes, bool) if view.node_active is None
                      else (view.node_active.max(axis=0) > 0))
            moved = 0
            for p in range(8):
                for q in range(8):
                    k = int(sg.plan.send_mask[p, q].sum())
                    mids = sg.plan.masters[p][sg.plan.send_idx[p, q, :k]]
                    moved += int(active[mids].sum())
            emit(f"fig10/amazon_like/{method}/{sname}", 0.0,
                 f"halo_values={moved};replica={stats['replica_factor']:.2f};"
                 f"mem_nodes={stats['memory_per_part_nodes']:.0f};"
                 f"edge_balance={stats['edge_balance']:.2f}")


def figA3_stage_breakdown():
    """Runtime share of each NN-TGAR stage for a 2-layer GCN mini-batch
    (papers100M analogue, scaled)."""
    import jax.numpy as jnp
    from repro.config import GNNConfig
    from repro.core.tgar import tree_take, combine_messages
    from repro.graph import make_dataset
    from repro.models import make_gnn

    g = make_dataset("reddit_like", num_nodes=4000, seed=0)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=128,
                    num_classes=8, feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
    view = next(mini_batch_views(g, 2, batch_nodes=400, seed=0))
    block = view.as_block()
    n = block.num_nodes_padded
    h = jnp.asarray(block.x)
    total = 0.0
    stage_us = {}
    for k, layer in enumerate(model.layers):
        lp = params["layers"][k]
        t_us = time_call(jax.jit(lambda p, x: layer.transform(p, x)), lp, h)
        nmsg = layer.transform(lp, h)
        g_fn = jax.jit(lambda p, nm: layer.gather(
            p, tree_take(nm, block.src), tree_take(nm, block.dst),
            block.edge_attr, jnp.asarray(block.edge_weight),
            jnp.asarray(block.edge_mask)))
        g_us = time_call(g_fn, lp, nmsg)
        msg = g_fn(lp, nmsg)
        s_fn = jax.jit(lambda m: combine_messages(
            layer, m, jnp.asarray(block.dst), n,
            jnp.asarray(block.edge_mask)))
        s_us = time_call(s_fn, msg)
        M = s_fn(msg)
        a_us = time_call(jax.jit(lambda p, x, m: layer.apply(p, x, m)),
                         lp, h, M)
        h = layer.apply(lp, h, M)
        stage_us[f"layer{k}"] = (t_us, g_us, s_us, a_us)
        total += t_us + g_us + s_us + a_us
    for k, (t, g_, s, a) in stage_us.items():
        emit(f"figA3/stage_breakdown/{k}", t + g_ + s + a,
             f"NN-T={100 * t / total:.1f}%;NN-G={100 * g_ / total:.1f}%;"
             f"Sum={100 * s / total:.1f}%;NN-A={100 * a / total:.1f}%")


def appB_halo_ablation(steps=60):
    """Paper App. B: cluster-batch with 0/1/2-hop boundary halos — the
    paper's extension over Cluster-GCN. Accuracy vs extra active nodes."""
    import jax
    from repro.config import GNNConfig
    from repro.core.mpgnn import accuracy_block, loss_block
    from repro.graph import make_dataset
    from repro.models import make_gnn
    from repro.optim import adam

    g = make_dataset("amazon_like", num_nodes=3000, seed=0).add_self_loops()
    cl = label_propagation_clusters(g, max_cluster_size=300, iters=4,
                                    seed=0)
    cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=64,
                    num_classes=int(g.labels.max()) + 1,
                    feature_dim=g.node_features.shape[1])
    model = make_gnn(cfg)
    for hops in (0, 1, 2):
        params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
        opt = adam(1e-2)
        state = opt.init(params)
        views = cluster_batch_views(g, 2, cl, clusters_per_batch=3,
                                    halo_hops=hops, seed=0)

        @jax.jit
        def step(params, state, block):
            loss, grads = jax.value_and_grad(
                lambda p: loss_block(model, p, block))(params)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        active = 0
        for _ in range(steps):
            v = next(views)
            active = max(active, v.active_counts()["active_nodes"])
            params, state, _ = step(params, state, v.as_block())
        gb = global_batch_view(g, 2).as_block()
        acc = None
        from repro.core.mpgnn import accuracy_block as ab
        acc = float(ab(model, params, gb,
                       mask=g.test_mask.astype(np.float32)))
        emit(f"appB/amazon_like/halo{hops}", 0.0,
             f"test_acc={acc:.4f};peak_active={active}")
