"""Benchmark driver — one function per paper table/figure.
Prints ``name,us_per_call,derived`` CSV (see DESIGN.md §6 for the mapping
to the paper's tables)."""
from __future__ import annotations

import argparse
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated benchmark names")
    ap.add_argument("--fast", action="store_true",
                    help="fewer training steps")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke mode (aggregate bench: tiny shapes, "
                         "one iteration, jaxpr contracts asserted)")
    args = ap.parse_args(argv)

    from benchmarks import gnn_tables, gnn_scaling, kernels_bench, \
        roofline_table, serving_bench, strategies_bench

    steps = 30 if args.fast else 60
    benches = {
        "table2": lambda: gnn_tables.table2_citation_accuracy(steps),
        "table3": lambda: gnn_tables.table3_strategies_accuracy(steps),
        "table4": lambda: gnn_tables.table4_strategy_tradeoffs(steps),
        "tableA2": lambda: gnn_tables.tableA2_gat_accuracy(steps),
        "fig8": gnn_scaling.fig8_scaling,
        "fig9": gnn_scaling.fig9_redundancy,
        "table5": gnn_scaling.table5_sampling_cost,
        "fig10": gnn_scaling.fig10_partitioning,
        "figA3": gnn_scaling.figA3_stage_breakdown,
        "appB": lambda: gnn_scaling.appB_halo_ablation(steps),
        "kernels": kernels_bench.kernels,
        "aggregate": lambda: kernels_bench.aggregate(smoke=args.smoke),
        "strategies": lambda: strategies_bench.strategies(smoke=args.smoke),
        "serving": lambda: serving_bench.serving(smoke=args.smoke),
        "roofline": roofline_table.roofline_table,
    }
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    for name, fn in benches.items():
        if only and name not in only:
            continue
        try:
            fn()
        except Exception as e:  # noqa: BLE001
            print(f"{name}/ERROR,0.0,{type(e).__name__}:{e}", flush=True)
            import traceback
            traceback.print_exc()
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
