"""Online GNN serving benchmark (PR 9 tentpole): latency/QPS for the
request-batched :class:`repro.serving.GNNServer` across batch size x
historical-embedding cache on/off x bucket ladder.

Each cell replays the same seeded skewed request trace (hot nodes
dominate — the regime a historical cache exists for) through the
synchronous ``submit`` inner loop: one warm pass (compiles the bucketed
steps and fills the cache), then a measured steady-state pass reporting
per-request p50/p99 latency, sustained QPS, per-stage time split and
cache hit rate. The compiled-once-per-bucket contract is asserted on
every cell.

Writes ``BENCH_serving.json``; the headline key is
``cache_beats_nocache_p50`` — the cache-hit fast path (1-hop view + top
layer only) must beat the full K-hop recompute at the median.

``--smoke`` is the CI lane: tiny trace, one batch size, plus hard
asserts — bit-exact cache-on vs cache-off parity at staleness 0 and the
per-bucket trace certificate.

    PYTHONPATH=src python -m benchmarks.serving_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import sys
import time

import numpy as np

from benchmarks.common import emit


def _measure(server, trace: np.ndarray, batch: int) -> dict:
    """Replay ``trace`` in ``batch``-sized submits; steady-state stats."""
    from repro.serving.server import ServeStats
    chunks = [trace[i:i + batch] for i in range(0, len(trace), batch)]
    for c in chunks:                      # warm pass: compile + fill cache
        server.submit(c)
    server.stats = ServeStats()           # measure steady state only
    t0 = time.perf_counter()
    for c in chunks:
        server.submit(c)
    wall = time.perf_counter() - t0
    server.assert_compiled_per_bucket()
    s = server.server_stats()
    lat = s["latency_ms"]
    return {
        "p50_ms": round(lat["p50"], 3), "p99_ms": round(lat["p99"], 3),
        "mean_ms": round(lat["mean"], 3),
        "qps": round(len(trace) / wall, 1),
        "wall_s": round(wall, 4),
        "stage_s": {k: round(v, 4) for k, v in s["stage_s"].items()},
        "hit_rate": round(s["cache"].get("hit_rate", 0.0), 3),
        "buckets": {"full": s["trace"]["full"]["buckets"],
                    "hit": s["trace"]["hit"]["buckets"]},
    }


def serving(smoke: bool = False, out_json: str = "BENCH_serving.json",
            requests: int = 0) -> dict:
    import repro.api as api
    from repro.core.views import BucketSpec
    from repro.launch.serve_gnn import request_trace

    if smoke and out_json == "BENCH_serving.json":
        out_json = "BENCH_serving_smoke.json"   # don't clobber nightly
    steps = 10 if smoke else 60
    n_req = requests or (64 if smoke else 1024)
    result = api.train(api.TrainJob(dataset="cora", steps=steps,
                                    hidden=32 if smoke else 64,
                                    eval_every=max(1, steps - 1)))
    g = result.graph
    trace = request_trace(g, n_req, seed=0)

    batch_sizes = (8,) if smoke else (1, 8, 32)
    ladders = {"ladder": None}
    if not smoke:
        # single max-size bucket: every view pads to graph capacity —
        # the "no ladder" ablation the size-bucketed menu is against
        big = BucketSpec.for_graph(g, levels=1)
        ladders["one_bucket"] = big

    cells = []
    for ladder_name, buckets in ladders.items():
        for batch in batch_sizes:
            for cache in (True, False):
                srv = api.serve(result, api.ServeConfig(
                    max_batch=batch, cache=cache, buckets=buckets))
                m = _measure(srv, trace, batch)
                cell = {"ladder": ladder_name, "max_batch": batch,
                        "cache": cache, **m}
                cells.append(cell)
                emit(f"serving/{ladder_name}/b{batch}/"
                     f"{'cache' if cache else 'nocache'}",
                     m["mean_ms"] * 1e3,
                     f"p50={m['p50_ms']}ms p99={m['p99_ms']}ms "
                     f"qps={m['qps']} hit={m['hit_rate']}")

    # headline: at the default ladder and mid batch size, the cache-hit
    # fast path must beat the full K-hop recompute at the median
    ref_batch = batch_sizes[min(1, len(batch_sizes) - 1)]
    ref = {(c["cache"]): c for c in cells
           if c["ladder"] == "ladder" and c["max_batch"] == ref_batch}
    beats = ref[True]["p50_ms"] < ref[False]["p50_ms"]

    if smoke:
        # hard contracts: staleness-0 parity, bit-exact, plus the
        # per-bucket certificate (already asserted per cell above)
        rng = np.random.default_rng(1)
        targets = rng.choice(g.num_nodes, 16, replace=False)
        cached = api.serve(result, api.ServeConfig(max_batch=16))
        plain = api.serve(result, api.ServeConfig(max_batch=16,
                                                  cache=False))
        cached.submit(targets)            # warm: all misses
        hit = cached.submit(targets)      # covered targets now hit
        if cached.cache.stats()["hits"] == 0:
            raise AssertionError("smoke trace produced no cache hits")
        if not np.array_equal(hit, plain.submit(targets)):
            raise AssertionError("cache-hit logits != full recompute")
        cached.assert_compiled_per_bucket()
        plain.assert_compiled_per_bucket()
        emit("serving/smoke_contracts", 0.0,
             "bit-exact cache parity + compiled-once-per-bucket")

    payload = {
        "model": {"dataset": "cora", "layers": result.model.K,
                  "hidden": 32 if smoke else 64, "final_acc":
                  round(float(result.final_acc), 4)},
        "trace": {"requests": n_req, "seed": 0, "skew": "10% hot / 80%"},
        "cells": cells,
        "cache_beats_nocache_p50": bool(beats),
        "cache_p50_speedup": round(
            ref[False]["p50_ms"] / max(ref[True]["p50_ms"], 1e-9), 3),
        "note": ("steady-state pass after a warm pass (compiles + cache "
                 "fill); per-request latency from the synchronous submit "
                 "loop; CPU wall-clock"),
    }
    if not smoke and not beats:
        raise AssertionError(
            f"historical-embedding cache lost at the median: "
            f"{ref[True]['p50_ms']}ms vs {ref[False]['p50_ms']}ms")
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json}", flush=True)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny trace, parity + trace contracts")
    ap.add_argument("--requests", type=int, default=0)
    ap.add_argument("--out", default="BENCH_serving.json")
    args = ap.parse_args(argv)
    serving(smoke=args.smoke, out_json=args.out, requests=args.requests)
    return 0


if __name__ == "__main__":
    sys.exit(main())
