"""§Roofline: render the per-(arch × shape × mesh) roofline table from the
dry-run artifacts in results/dryrun_*/ (produced by repro.launch.dryrun)."""
from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_GLOBS = ("results/dryrun_single/*.json", "results/dryrun_multi/*.json",
                 "results/perf/*.json")


def roofline_table():
    files = []
    for pat in RESULTS_GLOBS:
        files.extend(sorted(glob.glob(pat)))
    if not files:
        emit("roofline/NO_ARTIFACTS", 0.0,
             "run repro.launch.dryrun --all --out results/dryrun_single")
        return
    for f in files:
        with open(f) as fh:
            rec = json.load(fh)
        tag = rec.get("tag", os.path.basename(f))
        if rec["status"] == "skip":
            emit(f"roofline/{tag}", 0.0, f"SKIP:{rec['reason']}")
            continue
        if rec["status"] == "error":
            emit(f"roofline/{tag}", 0.0, f"ERROR:{rec['error'][:80]}")
            continue
        t_total = max(rec["t_compute_s"], rec["t_memory_s"],
                      rec["t_collective_s"])
        emit(f"roofline/{tag}", t_total * 1e6,
             f"t_compute={rec['t_compute_s']:.4e};"
             f"t_memory={rec['t_memory_s']:.4e};"
             f"t_collective={rec['t_collective_s']:.4e};"
             f"dominant={rec['dominant']};"
             f"useful_flops_ratio={rec['useful_flops_ratio']:.3f};"
             f"mem_GiB={rec['memory_per_device_bytes'] / 2**30:.2f}")
