"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle — plus the
end-to-end Sum-stage benchmark over the aggregation backends.

On this CPU container interpret-mode timings measure the Python emulation,
not TPU performance — the CSV documents call latency + the (shape, VMEM)
choices; TPU timing comes from running the same ops on hardware. The
``aggregate`` bench additionally writes BENCH_aggregate.json so successive
PRs can track the hot path (paper Fig. A3: 76% of runtime) end to end.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.ops import (assert_pregather_free, build_csc_plan,
                               flash_attention_op, segment_sum_op, wkv6_op)
from repro.kernels.ref import mha_ref, segment_sum_ref, wkv6_ref


def kernels():
    rng = np.random.default_rng(0)
    # segment sum: GNN aggregation hot spot (Fig. A3: 76% of runtime)
    E, N, D = 20000, 4000, 128
    ids = rng.integers(0, N, E).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    us = time_call(lambda d: segment_sum_op(d, plan, interpret=True), data,
                   iters=2)
    us_ref = time_call(
        lambda d: segment_sum_ref(d, jnp.asarray(ids), N), data, iters=2)
    emit("kernels/segment_sum_pallas_interp", us,
         f"E={E};N={N};D={D};jnp_ref_us={us_ref:.0f}")

    # wkv6
    B, T, H, K = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    us = time_call(lambda *a: wkv6_op(*a, chunk=64, interpret=True),
                   r, k, v, w, u, iters=2)
    us_ref = time_call(lambda *a: wkv6_ref(*a)[0], r, k, v, w, u, iters=2)
    emit("kernels/wkv6_pallas_interp", us,
         f"T={T};H={H};K={K};scan_ref_us={us_ref:.0f}")

    # flash attention
    B, T, Hh, Dh = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    us = time_call(lambda *a: flash_attention_op(
        *a, block_q=128, block_k=128, interpret=True), q, kk, vv, iters=2)
    us_ref = time_call(lambda *a: mha_ref(*a), q, kk, vv, iters=2)
    emit("kernels/flash_attention_pallas_interp", us,
         f"T={T};H={Hh};D={Dh};dense_ref_us={us_ref:.0f}")


def _sum_stage_traffic():
    """Fused-gather kernel vs the PR-1 pre-gather path: wall-clock and
    message-bytes moved through the Sum stage.

    The pre-gather path is reconstructed exactly: materialize the padded
    ``(nb, L_pad, D)`` layout in HBM, then run the same kernel over it with
    an identity gather (contiguous reads) — which is what PR 1 shipped.
    Also asserts (via the jaxpr) that the live fused path never allocates
    that layout.

    The graph is **skew-degree** (half the edges land on one destination
    block), the regime where pre-gathering hurts most: every block's edge
    slice pads to the hottest block's length, so the pre-gathered layout
    holds nb·L_pad ≈ 17·E message rows while the fused kernels keep
    reading the raw E rows. Interpret-mode wall-clock under-sells the gap
    (the Python emulation is per-grid-step bound, not bandwidth bound —
    on a uniform-degree graph, where nb·L_pad ≈ 1.2·E, it is a tie within
    noise) but at this skew the fused path wins it consistently; the
    bytes columns carry the hardware-relevant ratio.
    """
    import functools

    from repro.kernels.segment_sum import segment_sum_csc

    rng = np.random.default_rng(1)
    E, N, D = 20000, 4000, 64
    hot = rng.integers(0, 128, E // 2)           # one hot destination block
    cold = rng.integers(0, N, E - E // 2)
    ids = np.concatenate([hot, cold]).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    nb, l_pad = plan.gather_idx.shape

    import time as _time

    def best_of(fn, arg, n=5):
        """Min over n samples — interpret-mode emulation is bimodal (GC /
        allocator pauses), so the mean buries real differences; the min
        is the standard microbenchmark estimator for that regime."""
        jax.block_until_ready(fn(arg))                      # warmup
        samples = []
        for _ in range(n):
            t0 = _time.perf_counter()
            jax.block_until_ready(fn(arg))
            samples.append(_time.perf_counter() - t0)
        return min(samples) * 1e6

    # jit the fused wrapper so both sides time compiled dispatch (the
    # pregather emulation below is @jax.jit)
    fused = jax.jit(functools.partial(segment_sum_op, plan=plan,
                                      interpret=True))
    assert_pregather_free(jax.make_jaxpr(fused)(data), plan)
    us_fused = best_of(fused, data)

    ident = np.arange(nb * l_pad, dtype=np.int32).reshape(nb, l_pad)

    @jax.jit
    def pregather(d):
        padded = jnp.concatenate([d, jnp.zeros((1, D), d.dtype)], axis=0)
        gathered = padded[jnp.asarray(plan.gather_idx)]   # (nb, L_pad, D)
        return segment_sum_csc(gathered.reshape(nb * l_pad, D),
                               jnp.asarray(ident),
                               jnp.asarray(plan.local_ids), nb,
                               plan.block_n, plan.block_e,
                               interpret=True)[:N]

    us_pre = best_of(pregather, data)
    np.testing.assert_allclose(np.asarray(fused(data)),
                               np.asarray(pregather(data)),
                               rtol=1e-5, atol=1e-5)
    emit("aggregate/sum_stage_fused_gather", us_fused,
         f"E={E};N={N};D={D};pregather_us={us_pre:.0f}")
    return {
        "edges": E, "num_segments": N, "feature_dim": D,
        "plan_blocks": nb, "plan_l_pad": l_pad,
        # bytes of message data crossing HBM for one Sum-stage call:
        # fused reads the raw (E, D) once; pre-gather reads it, writes the
        # padded (nb, L_pad, D) layout, then the kernel reads that back
        "fused_message_bytes": 4 * E * D,
        "pregather_message_bytes": 4 * (E * D + 2 * nb * l_pad * D),
        "fused_us_per_call": round(us_fused, 1),
        "pregather_us_per_call": round(us_pre, 1),
        "fused_beats_pregather": bool(us_fused < us_pre),
    }


def aggregate(out_json: str = "BENCH_aggregate.json"):
    """End-to-end TGAR layer forward under each aggregation backend.

    Times ``forward_block`` (NN-T -> NN-G -> Sum -> NN-A, jitted) for one
    model per combine mode, "reference" vs "csc", and dumps the rows to
    ``out_json`` for the perf trajectory of the Sum-stage hot path — plus
    the fused-vs-pregather traffic comparison of ``_sum_stage_traffic``.
    """
    import dataclasses

    from repro.config import GNNConfig
    from repro.core.mpgnn import forward_block
    from repro.core.strategies import global_batch_view
    from repro.graph import sbm_graph
    from repro.models import make_gnn

    # traffic comparison first: it is timing-sensitive and the model loop
    # below leaves the process with enough jit-cache/allocator pressure
    # to skew interpret-mode samples taken after it
    traffic = _sum_stage_traffic()

    num_nodes, hidden = 2000, 32
    g = sbm_graph(num_nodes=num_nodes, num_classes=4, feature_dim=hidden,
                  p_in=0.01, p_out=0.002, seed=0).add_self_loops()
    rows = []
    for model_name, combine_mode, heads in (
            ("gcn", "sum", 1), ("sage", "mean", 1), ("sage_max", "max", 1),
            ("gat", "softmax", 4)):
        gcn_norm = model_name == "gcn"
        cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=hidden,
                        num_classes=4, feature_dim=hidden, num_heads=heads)
        model = make_gnn(cfg)
        params = model.init(jax.random.PRNGKey(0), hidden)
        view = global_batch_view(g, cfg.num_layers)
        for backend in ("reference", "csc"):
            m = dataclasses.replace(model, aggregate_backend=backend)
            block = view.as_block(gcn_norm=gcn_norm,
                                  csc_plan=backend == "csc")
            fwd = jax.jit(lambda p, b, m_=m: forward_block(m_, p, b))
            if backend == "csc":
                # the fused-gather contract, end to end through the model
                assert_pregather_free(jax.make_jaxpr(fwd)(params, block),
                                      block.csc_plan)
            us = time_call(fwd, params, block, iters=3)
            emit(f"aggregate/{model_name}_{backend}", us,
                 f"combine={combine_mode};N={g.num_nodes};E={g.num_edges};"
                 f"H={heads};D={hidden}")
            rows.append({"model": model_name, "combine": combine_mode,
                         "backend": backend, "us_per_call": round(us, 1),
                         "num_nodes": g.num_nodes,
                         "num_edges": g.num_edges,
                         "heads": heads, "hidden_dim": hidden,
                         "num_layers": cfg.num_layers,
                         "interpret_mode": jax.default_backend() != "tpu"})
    with open(out_json, "w") as f:
        json.dump({"benchmark": "aggregate_layer_forward",
                   "device": jax.default_backend(),
                   "note": ("csc timings are Pallas interpret-mode off-TPU "
                            "(Python emulation, not kernel speed); the "
                            "trajectory is meaningful per backend/device. "
                            "csc rows are fused-gather: verified free of "
                            "the (nb, L_pad, D) pre-gather tensor via "
                            "jaxpr walk"),
                   "sum_stage_traffic": traffic,
                   "rows": rows}, f, indent=2)
    print(f"wrote {out_json} ({len(rows)} rows)")
