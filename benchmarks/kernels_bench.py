"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle.

On this CPU container interpret-mode timings measure the Python emulation,
not TPU performance — the CSV documents call latency + the (shape, VMEM)
choices; TPU timing comes from running the same ops on hardware.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.ops import (build_csc_plan, flash_attention_op,
                               segment_sum_op, wkv6_op)
from repro.kernels.ref import mha_ref, segment_sum_ref, wkv6_ref


def kernels():
    rng = np.random.default_rng(0)
    # segment sum: GNN aggregation hot spot (Fig. A3: 76% of runtime)
    E, N, D = 20000, 4000, 128
    ids = rng.integers(0, N, E).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    us = time_call(lambda d: segment_sum_op(d, plan, interpret=True), data,
                   iters=2)
    us_ref = time_call(
        lambda d: segment_sum_ref(d, jnp.asarray(ids), N), data, iters=2)
    emit("kernels/segment_sum_pallas_interp", us,
         f"E={E};N={N};D={D};jnp_ref_us={us_ref:.0f}")

    # wkv6
    B, T, H, K = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    us = time_call(lambda *a: wkv6_op(*a, chunk=64, interpret=True),
                   r, k, v, w, u, iters=2)
    us_ref = time_call(lambda *a: wkv6_ref(*a)[0], r, k, v, w, u, iters=2)
    emit("kernels/wkv6_pallas_interp", us,
         f"T={T};H={H};K={K};scan_ref_us={us_ref:.0f}")

    # flash attention
    B, T, Hh, Dh = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    us = time_call(lambda *a: flash_attention_op(
        *a, block_q=128, block_k=128, interpret=True), q, kk, vv, iters=2)
    us_ref = time_call(lambda *a: mha_ref(*a), q, kk, vv, iters=2)
    emit("kernels/flash_attention_pallas_interp", us,
         f"T={T};H={Hh};D={Dh};dense_ref_us={us_ref:.0f}")
