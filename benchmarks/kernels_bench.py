"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle — plus the
end-to-end Sum-stage benchmark over the aggregation backends.

On this CPU container interpret-mode timings measure the Python emulation,
not TPU performance — the CSV documents call latency + the (shape, VMEM)
choices; TPU timing comes from running the same ops on hardware. The
``aggregate`` bench additionally writes BENCH_aggregate.json so successive
PRs can track the hot path (paper Fig. A3: 76% of runtime) end to end.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.kernels.ops import (build_csc_plan, flash_attention_op,
                               segment_sum_op, wkv6_op)
from repro.kernels.ref import mha_ref, segment_sum_ref, wkv6_ref


def kernels():
    rng = np.random.default_rng(0)
    # segment sum: GNN aggregation hot spot (Fig. A3: 76% of runtime)
    E, N, D = 20000, 4000, 128
    ids = rng.integers(0, N, E).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    us = time_call(lambda d: segment_sum_op(d, plan, interpret=True), data,
                   iters=2)
    us_ref = time_call(
        lambda d: segment_sum_ref(d, jnp.asarray(ids), N), data, iters=2)
    emit("kernels/segment_sum_pallas_interp", us,
         f"E={E};N={N};D={D};jnp_ref_us={us_ref:.0f}")

    # wkv6
    B, T, H, K = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    us = time_call(lambda *a: wkv6_op(*a, chunk=64, interpret=True),
                   r, k, v, w, u, iters=2)
    us_ref = time_call(lambda *a: wkv6_ref(*a)[0], r, k, v, w, u, iters=2)
    emit("kernels/wkv6_pallas_interp", us,
         f"T={T};H={H};K={K};scan_ref_us={us_ref:.0f}")

    # flash attention
    B, T, Hh, Dh = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    us = time_call(lambda *a: flash_attention_op(
        *a, block_q=128, block_k=128, interpret=True), q, kk, vv, iters=2)
    us_ref = time_call(lambda *a: mha_ref(*a), q, kk, vv, iters=2)
    emit("kernels/flash_attention_pallas_interp", us,
         f"T={T};H={Hh};D={Dh};dense_ref_us={us_ref:.0f}")


def aggregate(out_json: str = "BENCH_aggregate.json"):
    """End-to-end TGAR layer forward under each aggregation backend.

    Times ``forward_block`` (NN-T -> NN-G -> Sum -> NN-A, jitted) for one
    model per combine mode, "reference" vs "csc", and dumps the rows to
    ``out_json`` for the perf trajectory of the Sum-stage hot path.
    """
    import dataclasses

    from repro.config import GNNConfig
    from repro.core.mpgnn import forward_block
    from repro.core.strategies import global_batch_view
    from repro.graph import sbm_graph
    from repro.models import make_gnn

    num_nodes, hidden = 2000, 32
    g = sbm_graph(num_nodes=num_nodes, num_classes=4, feature_dim=hidden,
                  p_in=0.01, p_out=0.002, seed=0).add_self_loops()
    rows = []
    for model_name, combine_mode, heads in (
            ("gcn", "sum", 1), ("sage", "mean", 1), ("sage_max", "max", 1),
            ("gat", "softmax", 4)):
        gcn_norm = model_name == "gcn"
        cfg = GNNConfig(model=model_name, num_layers=2, hidden_dim=hidden,
                        num_classes=4, feature_dim=hidden, num_heads=heads)
        model = make_gnn(cfg)
        params = model.init(jax.random.PRNGKey(0), hidden)
        view = global_batch_view(g, cfg.num_layers)
        for backend in ("reference", "csc"):
            m = dataclasses.replace(model, aggregate_backend=backend)
            block = view.as_block(gcn_norm=gcn_norm,
                                  csc_plan=backend == "csc")
            fwd = jax.jit(lambda p, b, m_=m: forward_block(m_, p, b))
            us = time_call(fwd, params, block, iters=3)
            emit(f"aggregate/{model_name}_{backend}", us,
                 f"combine={combine_mode};N={g.num_nodes};E={g.num_edges};"
                 f"H={heads};D={hidden}")
            rows.append({"model": model_name, "combine": combine_mode,
                         "backend": backend, "us_per_call": round(us, 1),
                         "num_nodes": g.num_nodes,
                         "num_edges": g.num_edges,
                         "heads": heads, "hidden_dim": hidden,
                         "num_layers": cfg.num_layers,
                         "interpret_mode": jax.default_backend() != "tpu"})
    with open(out_json, "w") as f:
        json.dump({"benchmark": "aggregate_layer_forward",
                   "device": jax.default_backend(),
                   "note": ("csc timings are Pallas interpret-mode off-TPU "
                            "(Python emulation, not kernel speed); the "
                            "trajectory is meaningful per backend/device"),
                   "rows": rows}, f, indent=2)
    print(f"wrote {out_json} ({len(rows)} rows)")
