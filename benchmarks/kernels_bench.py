"""Kernel micro-benchmarks: Pallas (interpret) vs jnp oracle — plus the
end-to-end Sum-stage benchmark over the aggregation backends.

On this CPU container interpret-mode timings measure the Python emulation,
not TPU performance — the CSV documents call latency + the (shape, VMEM)
choices; TPU timing comes from running the same ops on hardware. The
``aggregate`` bench additionally writes BENCH_aggregate.json so successive
PRs can track the hot path (paper Fig. A3: 76% of runtime) end to end.
"""
from __future__ import annotations

import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_call
from repro.analysis import (JaxprContext, check_or_raise,
                            count_segment_scatters, run_rules)
from repro.kernels.ops import (build_csc_plan, flash_attention_op,
                               segment_sum_op, wkv6_op)
from repro.kernels.ref import mha_ref, segment_sum_ref, wkv6_ref

# the bench certifies through the repro.analysis rule registry (the
# ops-level assert_* shims remain for legacy callers)
SUM_STAGE_RULES = ("jaxpr.pregather", "jaxpr.segment-scatter",
                   "jaxpr.backward-gather")


def _check(closed_jaxpr, plan, ids):
    check_or_raise(run_rules(JaxprContext(closed_jaxpr, plan=plan),
                             ids=ids))


def kernels():
    rng = np.random.default_rng(0)
    # segment sum: GNN aggregation hot spot (Fig. A3: 76% of runtime)
    E, N, D = 20000, 4000, 128
    ids = rng.integers(0, N, E).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    us = time_call(lambda d: segment_sum_op(d, plan, interpret=True), data,
                   iters=2)
    us_ref = time_call(
        lambda d: segment_sum_ref(d, jnp.asarray(ids), N), data, iters=2)
    emit("kernels/segment_sum_pallas_interp", us,
         f"E={E};N={N};D={D};jnp_ref_us={us_ref:.0f}")

    # wkv6
    B, T, H, K = 1, 256, 4, 64
    r = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    k = jnp.asarray(rng.normal(size=(B, T, H, K)) * 0.5, jnp.float32)
    v = jnp.asarray(rng.normal(size=(B, T, H, K)), jnp.float32)
    w = jnp.asarray(0.6 + 0.39 * rng.random((B, T, H, K)), jnp.float32)
    u = jnp.asarray(rng.normal(size=(H, K)) * 0.2, jnp.float32)
    us = time_call(lambda *a: wkv6_op(*a, chunk=64, interpret=True),
                   r, k, v, w, u, iters=2)
    us_ref = time_call(lambda *a: wkv6_ref(*a)[0], r, k, v, w, u, iters=2)
    emit("kernels/wkv6_pallas_interp", us,
         f"T={T};H={H};K={K};scan_ref_us={us_ref:.0f}")

    # flash attention
    B, T, Hh, Dh = 1, 512, 4, 64
    q = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    kk = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    vv = jnp.asarray(rng.normal(size=(B, T, Hh, Dh)), jnp.float32)
    us = time_call(lambda *a: flash_attention_op(
        *a, block_q=128, block_k=128, interpret=True), q, kk, vv, iters=2)
    us_ref = time_call(lambda *a: mha_ref(*a), q, kk, vv, iters=2)
    emit("kernels/flash_attention_pallas_interp", us,
         f"T={T};H={Hh};D={Dh};dense_ref_us={us_ref:.0f}")


def _sum_stage_traffic():
    """Fused-gather kernel vs the PR-1 pre-gather path: wall-clock and
    message-bytes moved through the Sum stage.

    The pre-gather path is reconstructed exactly: materialize the padded
    ``(nb, L_pad, D)`` layout in HBM, then run the same kernel over it with
    an identity gather (contiguous reads) — which is what PR 1 shipped.
    Also asserts (via the jaxpr) that the live fused path never allocates
    that layout.

    The graph is **skew-degree** (half the edges land on one destination
    block), the regime where pre-gathering hurts most: every block's edge
    slice pads to the hottest block's length, so the pre-gathered layout
    holds nb·L_pad ≈ 17·E message rows while the fused kernels keep
    reading the raw E rows. Interpret-mode wall-clock under-sells the gap
    (the Python emulation is per-grid-step bound, not bandwidth bound —
    on a uniform-degree graph, where nb·L_pad ≈ 1.2·E, it is a tie within
    noise) but at this skew the fused path wins it consistently; the
    bytes columns carry the hardware-relevant ratio.
    """
    import functools

    from repro.kernels.segment_sum import segment_sum_csc

    rng = np.random.default_rng(1)
    E, N, D = 20000, 4000, 64
    hot = rng.integers(0, 128, E // 2)           # one hot destination block
    cold = rng.integers(0, N, E - E // 2)
    ids = np.concatenate([hot, cold]).astype(np.int32)
    data = jnp.asarray(rng.normal(size=(E, D)), jnp.float32)
    plan = build_csc_plan(ids, N)
    nb, l_pad = plan.gather_idx.shape

    # jit the fused wrapper so both sides time compiled dispatch (the
    # pregather emulation below is @jax.jit)
    fused = jax.jit(functools.partial(segment_sum_op, plan=plan,
                                      interpret=True))
    _check(jax.make_jaxpr(fused)(data), plan, ["jaxpr.pregather"])
    us_fused = _best_of(fused, data)

    ident = np.arange(nb * l_pad, dtype=np.int32).reshape(nb, l_pad)

    @jax.jit
    def pregather(d):
        padded = jnp.concatenate([d, jnp.zeros((1, D), d.dtype)], axis=0)
        gathered = padded[jnp.asarray(plan.gather_idx)]   # (nb, L_pad, D)
        return segment_sum_csc(gathered.reshape(nb * l_pad, D),
                               jnp.asarray(ident),
                               jnp.asarray(plan.local_ids), nb,
                               plan.block_n, plan.block_e,
                               interpret=True)[:N]

    us_pre = _best_of(pregather, data)
    np.testing.assert_allclose(np.asarray(fused(data)),
                               np.asarray(pregather(data)),
                               rtol=1e-5, atol=1e-5)
    emit("aggregate/sum_stage_fused_gather", us_fused,
         f"E={E};N={N};D={D};pregather_us={us_pre:.0f}")
    return {
        "edges": E, "num_segments": N, "feature_dim": D,
        "plan_blocks": nb, "plan_l_pad": l_pad,
        # bytes of message data crossing HBM for one Sum-stage call:
        # fused reads the raw (E, D) once; pre-gather reads it, writes the
        # padded (nb, L_pad, D) layout, then the kernel reads that back
        "fused_message_bytes": 4 * E * D,
        "pregather_message_bytes": 4 * (E * D + 2 * nb * l_pad * D),
        "fused_us_per_call": round(us_fused, 1),
        "pregather_us_per_call": round(us_pre, 1),
        "fused_beats_pregather": bool(us_fused < us_pre),
    }


def _best_of(fn, *args, n=5):
    """Min over n samples — interpret-mode emulation is bimodal (GC /
    allocator pauses), so the mean buries real differences; the min is
    the standard microbenchmark estimator for that regime."""
    import time as _time
    jax.block_until_ready(fn(*args))                      # warmup
    samples = []
    for _ in range(n):
        t0 = _time.perf_counter()
        jax.block_until_ready(fn(*args))
        samples.append(_time.perf_counter() - t0)
    return min(samples) * 1e6


def _backward_traffic():
    """Fused backward kernels vs the reconstructed PR-2 reference-math
    backward: wall-clock and message-bytes moved by one backward pass.

    Both sides run the SAME fused forward kernels; they differ only in
    the custom_vjp backward — the live path runs the plan-driven Pallas
    kernels (kernels/backward.py), the reconstruction re-attaches the old
    reference math (``g[segment_ids]`` jnp gathers; for softmax a full
    ``jax.ops.segment_max``/``segment_sum`` recompute plus three edge
    gathers), which is exactly what PR 2 shipped. Mirrors
    ``_sum_stage_traffic``: wall-clock carries the interpret-mode
    trajectory, the bytes columns carry the hardware-relevant ratio.

    Byte accounting (f32, message/edge tensors through HBM per call):

    - segment-sum bwd, fused: write d_data (E·D); the cotangent block
      (N·D) is a resident read. Reference: row-gather reads g (E·D) and
      writes d_data (E·D) — 2·E·D.
    - softmax bwd, fused: read logits (E·H) + values (E·H·D), write
      d_logits + d_values — 2·E·H·D + 2·E·H of edge traffic; p_e lives
      only in VMEM. Reference recompute: the two segment passes re-read
      the logits and materialize ex and p (4·E·H), the three edge
      gathers (g_e, out_e twice each: write+read = 4·E·H·D) plus values
      read and d_* writes — 7·E·H·D + 8·E·H in total.
    """
    from repro.core.aggregate import combine, reference_edge_softmax_bwd
    from repro.kernels.ops import edge_softmax_op

    rng = np.random.default_rng(2)
    E, N, D = 20000, 4000, 64
    H = 2
    ids = rng.integers(0, N, E).astype(np.int32)
    dst = jnp.asarray(ids)
    plan = build_csc_plan(ids, N)
    mask = jnp.ones(E, jnp.float32)
    value = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
    logit = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)

    def loss(mode, v, lg, backend, pln):
        out = combine(mode, {"value": v, "logit": lg}, dst, N, mask,
                      backend=backend, plan=pln)
        return jnp.sum(jnp.sin(out) * out)

    # -- reconstructed PR-2 path: fused forward, reference-math backward
    @jax.custom_vjp
    def _sum_refbwd(v):
        return segment_sum_op(v, plan, interpret=True)

    def _sum_refbwd_fwd(v):
        return _sum_refbwd(v), ()

    def _sum_refbwd_bwd(res, g):
        return (g[dst],)                       # the old g[segment_ids]

    _sum_refbwd.defvjp(_sum_refbwd_fwd, _sum_refbwd_bwd)

    @jax.custom_vjp
    def _softmax_refbwd(lg, v):
        return edge_softmax_op(lg, v, plan, interpret=True)

    def _softmax_refbwd_fwd(lg, v):
        out = _softmax_refbwd(lg, v)
        return out, (lg, v, out)

    def _softmax_refbwd_bwd(res, g):
        lg, v, out = res
        return reference_edge_softmax_bwd(g, lg, v, out, dst, N)

    _softmax_refbwd.defvjp(_softmax_refbwd_fwd, _softmax_refbwd_bwd)

    # -- segment-sum backward ------------------------------------------------
    def _sin_loss(out):
        return jnp.sum(jnp.sin(out) * out)

    fused_sum = jax.jit(jax.grad(lambda v: loss("sum", v, logit, "csc",
                                                plan)))
    recon_sum = jax.jit(jax.grad(lambda v: _sin_loss(_sum_refbwd(v))))
    np.testing.assert_allclose(np.asarray(fused_sum(value)),
                               np.asarray(recon_sum(value)),
                               rtol=1e-4, atol=1e-5)
    _check(jax.make_jaxpr(fused_sum)(value), plan, SUM_STAGE_RULES)
    us_sum_fused = _best_of(fused_sum, value)
    us_sum_recon = _best_of(recon_sum, value)
    emit("aggregate/segment_sum_bwd_fused", us_sum_fused,
         f"E={E};N={N};H={H};D={D};reference_bwd_us={us_sum_recon:.0f}")

    # -- edge-softmax backward -----------------------------------------------
    fused_sm = jax.jit(jax.grad(lambda lg, v: loss(
        "softmax", v, lg, "csc", plan), argnums=(0, 1)))
    recon_sm = jax.jit(jax.grad(
        lambda lg, v: _sin_loss(_softmax_refbwd(lg, v)), argnums=(0, 1)))
    for a, b in zip(fused_sm(logit, value), recon_sm(logit, value)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-5)
    _check(jax.make_jaxpr(fused_sm)(logit, value), plan, SUM_STAGE_RULES)
    us_sm_fused = _best_of(fused_sm, logit, value)
    us_sm_recon = _best_of(recon_sm, logit, value)
    emit("aggregate/edge_softmax_bwd_fused", us_sm_fused,
         f"E={E};N={N};H={H};D={D};reference_bwd_us={us_sm_recon:.0f}")

    f32 = 4
    sum_fused_bytes = f32 * E * D * H
    sum_ref_bytes = f32 * 2 * E * D * H
    sm_fused_bytes = f32 * (2 * E * H * D + 2 * E * H)
    sm_ref_bytes = f32 * (7 * E * H * D + 8 * E * H)
    return {
        "edges": E, "num_segments": N, "heads": H, "feature_dim": D,
        "segment_sum": {
            "fused_message_bytes": sum_fused_bytes,
            "reference_message_bytes": sum_ref_bytes,
            "fused_us_per_call": round(us_sum_fused, 1),
            "reference_us_per_call": round(us_sum_recon, 1),
        },
        "edge_softmax": {
            "fused_message_bytes": sm_fused_bytes,
            "reference_message_bytes": sm_ref_bytes,
            "fused_us_per_call": round(us_sm_fused, 1),
            "reference_us_per_call": round(us_sm_recon, 1),
        },
        # the acceptance line: the fused backward moves fewer message
        # bytes than the reconstructed reference backward
        "fused_beats_reference_bytes": bool(
            sum_fused_bytes < sum_ref_bytes
            and sm_fused_bytes < sm_ref_bytes),
        "note": ("wall-clock is interpret-mode emulation (trajectory "
                 "only); both sides share the fused forward, so the "
                 "delta is the backward swap"),
    }


def aggregate(out_json: str = "BENCH_aggregate.json", smoke: bool = False):
    """End-to-end TGAR layer forward AND train step (value_and_grad)
    under each aggregation backend.

    Times ``forward_block`` and ``value_and_grad(loss_block)`` (NN-T ->
    NN-G -> Sum -> NN-A plus the reverse flow, jitted) for one model per
    combine mode, "reference" vs "csc", and dumps the rows to
    ``out_json`` for the perf trajectory of the Sum-stage hot path — plus
    the fused-vs-pregather traffic comparison of ``_sum_stage_traffic``
    and the fused-vs-reference backward comparison of
    ``_backward_traffic``.

    ``smoke=True`` is the CI lane: tiny shapes, one timing iteration,
    and the full set of jaxpr contracts (pre-gather-free forward+backward,
    scatter-free combine-level value_and_grad, fewer segment scatters
    than the reference end to end) asserted so a contract regression
    fails the lane, not just the nightly bench.
    """
    import dataclasses

    from repro.config import GNNConfig
    from repro.core.mpgnn import forward_block, loss_block
    from repro.core.strategies import global_batch_view
    from repro.graph import sbm_graph
    from repro.models import make_gnn

    if smoke and out_json == "BENCH_aggregate.json":
        out_json = "BENCH_aggregate_smoke.json"   # don't clobber nightly

    # traffic comparisons first: they are timing-sensitive and the model
    # loop below leaves the process with enough jit-cache/allocator
    # pressure to skew interpret-mode samples taken after it
    # (the bytes comparison in backward_traffic is analytic accounting —
    # the enforced guards are the jaxpr contracts asserted inside the
    # traffic functions and the model loop below)
    traffic = _sum_stage_traffic() if not smoke else None
    bwd_traffic = _backward_traffic() if not smoke else None

    if smoke:
        num_nodes, hidden, layers, iters = 200, 8, 1, 1
    else:
        num_nodes, hidden, layers, iters = 2000, 32, 2, 3
    g = sbm_graph(num_nodes=num_nodes, num_classes=4, feature_dim=hidden,
                  p_in=0.01, p_out=0.002, seed=0).add_self_loops()
    rows = []
    scatter_counts = {}
    for model_name, combine_mode, heads in (
            ("gcn", "sum", 1), ("sage", "mean", 1), ("sage_max", "max", 1),
            ("gat", "softmax", 4)):
        gcn_norm = model_name == "gcn"
        cfg = GNNConfig(model=model_name, num_layers=layers,
                        hidden_dim=hidden, num_classes=4,
                        feature_dim=hidden, num_heads=heads)
        model = make_gnn(cfg)
        params = model.init(jax.random.PRNGKey(0), hidden)
        view = global_batch_view(g, cfg.num_layers)
        for backend in ("reference", "csc"):
            m = dataclasses.replace(model, aggregate_backend=backend)
            block = view.as_block(gcn_norm=gcn_norm,
                                  csc_plan=backend == "csc")
            fwd = jax.jit(lambda p, b, m_=m: forward_block(m_, p, b))
            vag = jax.jit(jax.value_and_grad(
                lambda p, b, m_=m: loss_block(m_, p, b)))
            plan = block.csc_plan
            if backend == "csc":
                # the fused-gather contract, end to end through the model
                # — forward AND backward (the train-step jaxpr)
                _check(jax.make_jaxpr(fwd)(params, block), plan,
                       ["jaxpr.pregather"])
                _check(jax.make_jaxpr(lambda p: vag(p, block))(params),
                       plan, ["jaxpr.pregather"])
            scatter_counts[(model_name, backend)] = (
                count_segment_scatters(
                    jax.make_jaxpr(lambda p: vag(p, block))(params),
                    block.csc_plan or view.as_block(
                        gcn_norm=gcn_norm, csc_plan=True).csc_plan))
            for phase, fn in (("forward", fwd), ("value_and_grad", vag)):
                us = time_call(fn, params, block, iters=iters)
                emit(f"aggregate/{model_name}_{backend}_{phase}", us,
                     f"combine={combine_mode};N={g.num_nodes};"
                     f"E={g.num_edges};H={heads};D={hidden}")
                rows.append({"model": model_name, "combine": combine_mode,
                             "backend": backend, "phase": phase,
                             "us_per_call": round(us, 1),
                             "num_nodes": g.num_nodes,
                             "num_edges": g.num_edges,
                             "heads": heads, "hidden_dim": hidden,
                             "num_layers": cfg.num_layers,
                             "interpret_mode":
                                 jax.default_backend() != "tpu"})
        # the Sum-stage fallbacks are gone from the train step: only the
        # NN-Gather transposes (shared by both backends) may remain
        assert (scatter_counts[(model_name, "csc")]
                < scatter_counts[(model_name, "reference")]), (
            model_name, scatter_counts)

    if smoke:
        # combine-level certificate: the exact scatter/gather-free
        # contract of the fused backward, all four modes
        from repro.core.aggregate import combine
        rng = np.random.default_rng(0)
        E, N, H, D = 300, 64, 2, 8
        ids = rng.integers(0, N, E).astype(np.int32)
        dst = jnp.asarray(ids)
        cplan = build_csc_plan(ids, N, block_n=32, block_e=64)
        value = jnp.asarray(rng.normal(size=(E, H, D)), jnp.float32)
        logit = jnp.asarray(rng.normal(size=(E, H)), jnp.float32)
        mask = jnp.asarray(rng.random(E) > 0.2, jnp.float32)
        for mode in ("sum", "mean", "max", "softmax"):
            def closs(v, lg):
                out = combine(mode, {"value": v, "logit": lg}, dst, N,
                              mask, backend="csc", plan=cplan)
                return jnp.sum(out * out)

            _check(
                jax.make_jaxpr(jax.value_and_grad(closs, argnums=(0, 1)))(
                    value, logit), cplan, SUM_STAGE_RULES)
            emit(f"aggregate/contract_{mode}", 0.0, "sum_stage_fused=ok")

    with open(out_json, "w") as f:
        json.dump({"benchmark": "aggregate_layer_forward",
                   "device": jax.default_backend(),
                   "smoke": smoke,
                   "note": ("csc timings are Pallas interpret-mode off-TPU "
                            "(Python emulation, not kernel speed); the "
                            "trajectory is meaningful per backend/device. "
                            "csc rows are fused-gather, forward and "
                            "backward: verified free of the (nb, L_pad, "
                            "D) pre-gather tensor via jaxpr walk, and the "
                            "train step carries no Sum-stage reference "
                            "segment fallbacks"),
                   "sum_stage_traffic": traffic,
                   "backward_traffic": bwd_traffic,
                   "segment_scatter_counts": {
                       f"{m}/{b}": c
                       for (m, b), c in scatter_counts.items()},
                   "rows": rows}, f, indent=2)
    print(f"wrote {out_json} ({len(rows)} rows)")
