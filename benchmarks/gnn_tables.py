"""Paper Tables 2/3/4/A2 analogues on synthetic stand-in datasets.

The absolute accuracies are not comparable to the paper (offline synthetic
graphs); what is reproduced is the paper's *claims*: non-sampling GB/MB/CB
learn equally-good models (Tables 2–3), cluster-batch converges fastest on
the power-law edge-attributed graph (Table 4), and GAT parity (Table A2).
"""
from __future__ import annotations

import time

import jax
import numpy as np

from benchmarks.common import emit, binary_auc, f1_score
from repro.launch.train import train_gnn


def table2_citation_accuracy(steps=60):
    """GCN w/ GB and MB on the three citation stand-ins."""
    for ds in ("cora", "citeseer", "pubmed"):
        for strategy in ("global", "mini"):
            t0 = time.perf_counter()
            out = train_gnn(ds, "gcn", strategy,
                            steps=steps if strategy == "global"
                            else steps * 4,
                            hidden=16, eval_every=10 ** 9)
            us = (time.perf_counter() - t0) * 1e6 / max(steps, 1)
            emit(f"table2/{ds}/gcn_{strategy}", us,
                 f"test_acc={out['final_acc']:.4f}")


def table3_strategies_accuracy(steps=80):
    """GB / MB / CB / sampled-MB on dense community graphs."""
    from repro.core.clustering import label_propagation_clusters
    from repro.core.strategies import mini_batch_views
    for ds in ("reddit_like", "amazon_like"):
        for strategy in ("global", "mini", "cluster"):
            t0 = time.perf_counter()
            out = train_gnn(ds, "gcn", strategy, steps=steps, hidden=64,
                            eval_every=10 ** 9)
            us = (time.perf_counter() - t0) * 1e6 / steps
            emit(f"table3/{ds}/gcn_{strategy}", us,
                 f"test_acc={out['final_acc']:.4f}")


def table4_strategy_tradeoffs(steps=60):
    """GAT-E on the alipay-like power-law graph: F1/AUC/time/peak-active
    per strategy (the paper's Table 4 columns)."""
    from repro.config import GNNConfig
    from repro.core.clustering import label_propagation_clusters
    from repro.core.mpgnn import forward_block, loss_block
    from repro.core.strategies import (cluster_batch_views,
                                       global_batch_view, mini_batch_views)
    from repro.graph import make_dataset
    from repro.models import make_gnn
    from repro.optim import adam
    import jax.numpy as jnp

    g = make_dataset("alipay_like", num_nodes=4000, seed=0)
    cfg = GNNConfig(model="gat_e", num_layers=2, hidden_dim=32,
                    num_classes=2, feature_dim=g.node_features.shape[1],
                    edge_feature_dim=g.edge_features.shape[1], num_heads=4)
    model = make_gnn(cfg)
    cl = label_propagation_clusters(g, max_cluster_size=400, iters=4,
                                    seed=0)
    for strategy in ("global", "mini", "cluster"):
        params = model.init(jax.random.PRNGKey(0), cfg.feature_dim)
        opt = adam(5e-3)
        state = opt.init(params)
        if strategy == "global":
            views = iter(lambda: global_batch_view(g, 2), None)
        elif strategy == "mini":
            views = mini_batch_views(g, 2, batch_nodes=400, seed=0)
        else:
            views = cluster_batch_views(g, 2, cl, clusters_per_batch=3,
                                        halo_hops=1, seed=0)

        @jax.jit
        def step(params, state, block):
            loss, grads = jax.value_and_grad(
                lambda p: loss_block(model, p, block))(params)
            params, state = opt.update(grads, state, params)
            return params, state, loss

        n_steps = steps if strategy == "global" else steps * 3
        peak_active = 0
        t0 = time.perf_counter()
        for _ in range(n_steps):
            v = next(views)
            peak_active = max(peak_active, v.active_counts()["active_nodes"])
            params, state, loss = step(params, state,
                                       v.as_block(gcn_norm=False))
        wall = time.perf_counter() - t0
        steps_run = n_steps
        gb = global_batch_view(g, 2).as_block(gcn_norm=False)
        logits = np.asarray(forward_block(model, params, gb))[:g.num_nodes]
        test = g.test_mask
        scores = jax.nn.softmax(jnp.asarray(logits), -1)[:, 1]
        auc = binary_auc(g.labels[test], np.asarray(scores)[test])
        f1 = f1_score(g.labels[test], logits.argmax(-1)[test])
        emit(f"table4/alipay_like/gat_e_{strategy}",
             wall * 1e6 / steps_run,
             f"f1={f1:.4f};auc={auc:.4f};peak_active={peak_active}")


def tableA2_gat_accuracy(steps=60):
    for ds in ("cora", "citeseer", "pubmed"):
        for strategy in ("global", "mini"):
            out = train_gnn(ds, "gat", strategy,
                            steps=steps if strategy == "global"
                            else steps * 4,
                            hidden=16, eval_every=10 ** 9)
            emit(f"tableA2/{ds}/gat_{strategy}",
                 out["wall_s"] * 1e6 / steps,
                 f"test_acc={out['final_acc']:.4f}")
