"""Strategy-Trainer benchmark (paper §2.3/§4.3 + DistDGL's host-bottleneck
observation): steps/sec for global-, mini- and cluster-batch under each
aggregation backend, comparing

  * ``naive``            — the pre-Trainer loop: per-partition Python
                           ``shard_view_loop`` + blocking ``device_put``
                           rebuild every step (what the examples used to
                           hand-roll),
  * ``trainer``          — compiled-once Trainer, vectorized ``shard_view``,
                           prefetch disabled,
  * ``trainer_prefetch`` — the full double-buffered host pipeline.

A ``view_build`` section times host-side view construction itself
(views/sec): the per-node Python BFS loop vs the vectorized CSR-segment
expansion vs the buffer-reusing ViewBuilder for mini-batch views, and the
per-step ``np.isin``+halo recompute vs the precomputed ClusterViewCache
for cluster views.

A ``prefetch_mode`` section (PR 10 tentpole) consumes one build-heavy
mini-batch stream through the in-process thread pool vs the supervised
shared-memory sampler processes: bit-identical emission is asserted in
both lanes, and full mode hard-asserts process views/sec >= thread on
multi-core hosts (a single-core box cannot parallelize the builds, so
there the measurement is recorded but not enforced).

A ``compact_views`` section (PR 6 tentpole) scales the graph at a fixed
fan-out (batch size + neighbor cap, degree held constant) and compares
the dense mask path against the compact sampled-subgraph path: per-view
host bytes and build time (dense grows with N, compact must stay ~flat)
and end-to-end steps/sec through the bucketed CompactTrainer (dense
full-graph staging vs size-bucketed compact blocks).

Writes ``BENCH_strategies.json``. ``--smoke`` is the CI lane: tiny shapes
plus the contracts asserted — exactly one trace of the train step across
N steps of *all three* strategies, bit-exact parity of the vectorized
``shard_view`` with the per-partition loop, bit-exact parity of the
vectorized/cached view builders with their loop/recompute oracles,
bit-exact compact-vs-dense masks plus the once-per-bucket trace count,
and bit-identical trainer loss trajectories for prefetch_workers in
{1, 4} and prefetch disabled (multi-stream determinism).

Standalone (sets fake host devices before importing jax):

    PYTHONPATH=src python -m benchmarks.strategies_bench [--smoke]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time


def _run_naive(engine, step_fn, opt, views, steps: int):
    """The per-step rebuild baseline: loop shard_view + blocking staging.

    This reproduces the hand-rolled loop the repo shipped before the
    Trainer (examples + ``launch/train.py``): per-partition
    ``shard_view_loop``, blocking ``device_put`` staging, and a per-step
    ``float(loss)`` readback for logging — the sync that serializes host
    view prep with device compute. ``step_fn`` is built (and warmed) once
    per backend so the baseline is not charged for compiles.
    """
    import jax

    from repro.core.strategies import shard_view_loop

    model = engine.model
    params = model.init(jax.random.PRNGKey(0), engine.sg.feature_dim)
    opt_state = opt.init(params)
    # warmup x2: the first step compiles for uncommitted params, the
    # second for the committed/replicated params every later step sees
    for _ in range(2):
        params, opt_state, loss = step_fn(
            params, opt_state, shard_view_loop(engine.plan, next(views)))
    jax.block_until_ready(loss)
    t0 = time.perf_counter()
    for _ in range(steps):
        view = next(views)
        params, opt_state, loss = step_fn(params, opt_state,
                                          shard_view_loop(engine.plan, view))
        loss = float(loss)   # the old loops' per-step logging sync
    return time.perf_counter() - t0


def _view_build_section(g, K: int, clusters, smoke: bool) -> dict:
    """Time view construction alone (no device work): loop vs vectorized
    vs builder for mini-batch k-hop views, recompute vs cached for
    cluster views. Parity of every fast path against its oracle is
    hard-asserted (bit-exact masks) before timing."""
    import numpy as np

    from benchmarks.common import emit
    from repro.core.subgraph import bfs_layers_loop, khop_subgraph_view
    from repro.core.views import (ClusterViewCache, ViewBuilder,
                                  cluster_view_recompute)

    K = int(K)
    N, E = g.num_nodes, g.num_edges
    train = (g.train_mask if g.train_mask is not None
             else np.ones(N, bool))
    labeled = np.where(train)[0]
    rng = np.random.default_rng(0)
    n_views = 3 if smoke else 10
    repeats = 1 if smoke else 5
    halo = 2
    bsz = min(max(16, 3 * N // 8), len(labeled))
    targets = [rng.choice(labeled, size=bsz, replace=False)
               for _ in range(n_views)]
    C = int(clusters.max()) + 1
    cpb = min(max(1, C // 4), C)
    chosen = [rng.choice(C, size=cpb, replace=False)
              for _ in range(n_views)]

    t0 = time.perf_counter()
    cache = ClusterViewCache(g, clusters, halo)
    cache_build_s = time.perf_counter() - t0
    vb = ViewBuilder(g, K)

    # -- parity contracts (bit-exact masks, asserted in smoke AND full) ------
    for t in targets[:2]:
        na, ea, lm, _ = khop_subgraph_view(g, t, K, _bfs=bfs_layers_loop)
        v = vb.khop_view(t)
        assert np.array_equal(v.node_active, na), "khop node mask diverges"
        assert np.array_equal(v.edge_active, ea), "khop edge mask diverges"
        assert np.array_equal(v.loss_mask, lm), "khop loss mask diverges"
    for ch in chosen[:2]:
        member, active, loss = cluster_view_recompute(g, clusters, ch,
                                                      halo, train)
        v = vb.cluster_view(ch, cache, train)
        assert np.array_equal(
            v.node_active,
            np.broadcast_to(active.astype(np.float32), (K, N))), \
            "cluster node mask diverges"
        assert np.array_equal(
            v.edge_active,
            np.broadcast_to((active[g.src] & active[g.dst])
                            .astype(np.float32), (K, E))), \
            "cluster edge mask diverges"
        assert np.array_equal(v.loss_mask, loss), "cluster loss diverges"
    emit("strategies/contract_view_parity", 0.0,
         "builder==loop-oracle;cached==recompute-oracle")

    def mini_loop():
        for t in targets:
            khop_subgraph_view(g, t, K, _bfs=bfs_layers_loop)

    def mini_vectorized():
        for t in targets:
            khop_subgraph_view(g, t, K)

    def mini_builder():
        for t in targets:
            vb.khop_view(t)

    def cluster_recompute():
        # the pre-cache path end to end: isin + halo walks + dense masks
        for ch in chosen:
            member, active, loss = cluster_view_recompute(g, clusters, ch,
                                                          halo, train)
            np.broadcast_to(active.astype(np.float32), (K, N)).copy()
            np.broadcast_to((active[g.src] & active[g.dst])
                            .astype(np.float32), (K, E)).copy()

    def cluster_cached():
        for ch in chosen:
            vb.cluster_view(ch, cache, train)

    variants = {"mini_loop": mini_loop, "mini_vectorized": mini_vectorized,
                "mini_builder": mini_builder,
                "cluster_recompute": cluster_recompute,
                "cluster_cached": cluster_cached}
    walls = {k: float("inf") for k in variants}
    names = list(variants)
    for r in range(repeats):
        for k in names[r % len(names):] + names[: r % len(names)]:
            fn = variants[k]
            t0 = time.perf_counter()
            fn()
            walls[k] = min(walls[k], time.perf_counter() - t0)
    vps = {k: n_views / w for k, w in walls.items()}
    for k, v in vps.items():
        emit(f"strategies/view_build_{k}",
             walls[k] / n_views * 1e6, f"views_per_sec={v:.1f}")
    return {
        "n_views": n_views, "repeats": repeats, "halo_hops": halo,
        "batch_nodes": int(bsz), "clusters_per_batch": int(cpb),
        "num_nodes": N, "num_edges": E, "K": K,
        "cache_build_s": round(cache_build_s, 5),
        "views_per_sec": {k: round(v, 1) for k, v in vps.items()},
        "ms_per_view": {k: round(w / n_views * 1e3, 4)
                        for k, w in walls.items()},
        "vectorized_speedup_vs_loop": round(
            walls["mini_loop"] / walls["mini_vectorized"], 2),
        "builder_speedup_vs_loop": round(
            walls["mini_loop"] / walls["mini_builder"], 2),
        "cached_speedup_vs_recompute": round(
            walls["cluster_recompute"] / walls["cluster_cached"], 2),
        "vectorized_beats_loop": bool(
            walls["mini_vectorized"] < walls["mini_loop"]),
        "builder_beats_loop": bool(
            walls["mini_builder"] < walls["mini_loop"]),
        "cached_beats_recompute": bool(
            walls["cluster_cached"] < walls["cluster_recompute"]),
    }


def _compact_views_section(smoke: bool) -> dict:
    """Dense masks vs compact sampled-subgraph views as the graph grows
    at a fixed fan-out. Measures per-view host bytes, per-view build time
    (builders timed directly; target draws are shared setup) and
    steps/sec through the bucketed CompactTrainer. Compact-vs-dense mask
    parity and the once-per-bucket trace contract are hard-asserted in
    smoke AND full mode."""
    import numpy as np

    from benchmarks.common import emit
    from repro.config import GNNConfig
    from repro.core.strategies import strategy_views
    from repro.core.trainer import CompactTrainer
    from repro.core.views import ViewBuilder
    from repro.graph import sbm_graph
    from repro.models import make_gnn
    from repro.optim import adam

    sizes = [300, 900] if smoke else [1000, 3200, 10000]
    # fan-out kept small enough that the sampled view saturates well below
    # the largest graph (16 targets, cap 4, K=2 -> <= ~336 nodes): past
    # saturation the per-view cost curve separates from the graph size
    K, bsz, cap = 2, 16, 4
    n_views = 4 if smoke else 12
    steps = 3 if smoke else 10
    repeats = 2 if smoke else 3
    feat = 16
    cfg = GNNConfig(model="gcn", num_layers=K, hidden_dim=16,
                    num_classes=4, feature_dim=feat)
    model = make_gnn(cfg)
    opt = adam(1e-2)
    scales = []
    for N in sizes:
        # p ~ 1/N holds the degree fixed as N grows: the view the fan-out
        # samples stays the same size while the dense (K,N)/(K,E) masks
        # track the graph — exactly the scaling the compact path removes
        g = sbm_graph(num_nodes=N, num_classes=4, feature_dim=feat,
                      p_in=24.0 / N, p_out=2.4 / N, seed=0,
                      name=f"scale{N}").add_self_loops()

        # -- parity contract (bit-exact masks from the same index) ----------
        dense_s = strategy_views(g, "mini", K, seed=0, batch_nodes=bsz,
                                 neighbor_cap=cap)
        comp_s = strategy_views(g, "mini", K, seed=0, batch_nodes=bsz,
                                neighbor_cap=cap, compact=True)
        for i in range(2):
            dv = dense_s.build(i).copy_masks()
            cv = comp_s.build(i)
            cd = cv.to_dense()
            assert np.array_equal(cd.node_active, dv.node_active), N
            assert np.array_equal(cd.edge_active, dv.edge_active), N
            assert np.array_equal(cd.loss_mask, dv.loss_mask), N

        # -- per-view build time + host bytes ------------------------------
        rng = np.random.default_rng(0)
        labeled = np.where(g.train_mask)[0]
        targets = [rng.choice(labeled, size=min(bsz, len(labeled)),
                              replace=False) for _ in range(n_views)]
        dense_vb = ViewBuilder(g, K)
        compact_vb = ViewBuilder(g, K, compact=True)
        walls = {"dense": float("inf"), "compact": float("inf")}
        for _ in range(max(2, repeats)):      # first pass warms scratch
            t0 = time.perf_counter()
            for t in targets:
                dense_vb.khop_view(t, cap, np.random.default_rng(1))
            walls["dense"] = min(walls["dense"], time.perf_counter() - t0)
            t0 = time.perf_counter()
            for t in targets:
                compact_vb.khop_compact(t, cap, np.random.default_rng(1))
            walls["compact"] = min(walls["compact"],
                                   time.perf_counter() - t0)
        cv = compact_vb.khop_compact(targets[0], cap,
                                     np.random.default_rng(1))
        dense_bytes = 4 * (K * g.num_nodes + K * g.num_edges
                           + g.num_nodes)

        # -- steps/sec: CompactTrainer over dense vs compact streams -------
        sps = {}
        for compact in (False, True):
            trainer = CompactTrainer(model, g, opt, seed=0)

            def stream():
                return strategy_views(g, "mini", K, seed=3,
                                      batch_nodes=bsz, neighbor_cap=cap,
                                      compact=compact)

            # warm the full step sequence once: every bucket the timed run
            # touches is compiled before timing starts
            trainer.fit(stream(), steps=steps, prefetch=False)
            wall = float("inf")
            for _ in range(repeats):
                trainer.reset(seed=0)
                t0 = time.perf_counter()
                trainer.fit(stream(), steps=steps, prefetch=False)
                wall = min(wall, time.perf_counter() - t0)
            # the bucket-trace contract: one trace per touched shape,
            # repeat epochs added zero
            trainer.assert_compiled_per_bucket()
            assert (trainer.trace_counts["train_step"]
                    == len(trainer.buckets_touched))
            sps[compact] = steps / wall
        emit(f"strategies/compact_views_N{N}",
             walls["compact"] / n_views * 1e6,
             f"dense_us={walls['dense'] / n_views * 1e6:.1f};"
             f"bytes={cv.nbytes()}(dense {dense_bytes});"
             f"sps={sps[True]:.2f}(dense {sps[False]:.2f})")
        scales.append({
            "num_nodes": g.num_nodes, "num_edges": g.num_edges,
            "view_nodes": cv.num_nodes, "view_edges": cv.num_edges,
            "dense_bytes_per_view": dense_bytes,
            "compact_bytes_per_view": cv.nbytes(),
            "dense_ms_per_view": round(walls["dense"] / n_views * 1e3, 4),
            "compact_ms_per_view": round(
                walls["compact"] / n_views * 1e3, 4),
            "dense_views_per_sec": round(n_views / walls["dense"], 1),
            "compact_views_per_sec": round(n_views / walls["compact"], 1),
            "steps_per_sec_dense": round(sps[False], 3),
            "steps_per_sec_compact": round(sps[True], 3),
        })
    emit("strategies/contract_compact_parity", 0.0,
         "compact.to_dense()==dense;once-per-bucket")

    first, last = scales[0], scales[-1]

    def growth(key):
        return round(last[key] / max(first[key], 1e-9), 2)

    return {
        "sizes": sizes, "K": K, "batch_nodes": bsz, "neighbor_cap": cap,
        "n_views": n_views, "steps": steps, "scales": scales,
        "n_growth": growth("num_nodes"),
        "dense_bytes_growth": growth("dense_bytes_per_view"),
        "compact_bytes_growth": growth("compact_bytes_per_view"),
        "dense_build_growth": growth("dense_ms_per_view"),
        "compact_build_growth": growth("compact_ms_per_view"),
        "compact_bytes_flat_2x": bool(
            growth("compact_bytes_per_view") <= 2.0),
        "compact_build_flat_2x": bool(
            growth("compact_ms_per_view") <= 2.0),
        "compact_sps_ge_dense_at_largest": bool(
            last["steps_per_sec_compact"] >= last["steps_per_sec_dense"]),
    }


def _prefetch_mode_section(smoke: bool) -> dict:
    """Thread- vs process-pool view construction (PR 10 tentpole):
    the same build-heavy mini-batch stream consumed through the
    in-process :class:`StreamPrefetcher` and the shared-memory
    :class:`ProcessViewService`. The first view is consumed before the
    clock starts (it absorbs process spawn + child imports — a fixed
    cost the steady state never pays), emission parity is hard-asserted
    in smoke AND full, and in full mode the GIL-free sampler processes
    must at least match the thread pool (views/sec) on this cell."""
    import numpy as np

    from benchmarks.common import emit
    from repro.core.strategies import strategy_views
    from repro.graph import sbm_graph
    from repro.runtime import (ProcessViewService, StreamPrefetcher,
                               shared_memory_available)

    if not shared_memory_available():
        return {"skipped": "multiprocessing.shared_memory unavailable"}
    if smoke:
        N, bsz, n_views, repeats = 600, 64, 6, 1
    else:
        N, bsz, n_views, repeats = 8000, 512, 24, 2
    workers = 2
    K = 2
    g = sbm_graph(num_nodes=N, num_classes=4, feature_dim=16,
                  p_in=32.0 / N, p_out=3.2 / N, seed=0,
                  name=f"pf{N}").add_self_loops()
    g.csc()          # shared setup: neither mode is charged for the plan

    def stream():
        return strategy_views(g, "mini", K, seed=0, batch_nodes=bsz,
                              compact=True)

    pools = {"thread": StreamPrefetcher, "process": ProcessViewService}

    def run(mode):
        svc = pools[mode](stream(), lambda v: v, n_views,
                          workers=workers)
        try:
            it = iter(svc)
            first = next(it)
            t0 = time.perf_counter()
            rest = list(it)
            wall = time.perf_counter() - t0
        finally:
            svc.close()
        return [first] + rest, wall

    walls = {m: float("inf") for m in pools}
    emitted = {}
    for r in range(repeats):
        for m in pools:
            views, wall = run(m)
            emitted[m] = views
            walls[m] = min(walls[m], wall)
    # parity: both pools emit the identical view sequence
    for va, vb in zip(emitted["thread"], emitted["process"]):
        for f in ("nodes", "src_local", "dst_local", "loss_local"):
            assert np.array_equal(getattr(va, f), getattr(vb, f)), (
                f"prefetch_mode parity broke on {f}")
    emit("strategies/contract_prefetch_mode_parity", 0.0,
         "process==thread emission")
    vps = {m: (n_views - 1) / w for m, w in walls.items()}
    for m, v in vps.items():
        emit(f"strategies/prefetch_mode_{m}",
             walls[m] / (n_views - 1) * 1e6,
             f"views_per_sec={v:.1f};workers={workers};N={N}")
    process_ge_thread = bool(vps["process"] >= vps["thread"])
    cores = os.cpu_count() or 1
    # the claim needs actual parallelism: on a single-core box both
    # pools serialize on the one CPU and the process pool can only add
    # IPC overhead, so the >= gate is asserted on multi-core hosts and
    # recorded (not enforced) otherwise
    if not smoke and cores >= 2:
        assert process_ge_thread, (
            "process-pool sampling slower than the thread pool on the "
            f"build-heavy mini-batch cell: {vps}")
    return {
        "num_nodes": N, "batch_nodes": bsz, "K": K, "cores": cores,
        "workers": workers, "n_views": n_views, "repeats": repeats,
        "views_per_sec": {m: round(v, 1) for m, v in vps.items()},
        "ms_per_view": {m: round(w / (n_views - 1) * 1e3, 4)
                        for m, w in walls.items()},
        "process_speedup_vs_thread": round(
            walls["thread"] / walls["process"], 3),
        "process_ge_thread": process_ge_thread,
    }


def _assert_multistream_determinism(trainer, views_for) -> None:
    """The multi-stream prefetch contract: loss trajectories are
    bit-identical for prefetch_workers in {1, 4} and prefetch off."""
    for strategy in ("mini", "cluster"):
        ref = None
        for kwargs in ({"prefetch": False},
                       {"prefetch": True, "prefetch_workers": 1},
                       {"prefetch": True, "prefetch_workers": 4}):
            trainer.reset(seed=0)
            losses = trainer.fit(views_for(strategy, seed=17), steps=3,
                                 **kwargs)["losses"]
            if ref is None:
                ref = losses
            assert losses == ref, (
                f"multi-stream prefetch broke determinism: {strategy} "
                f"{kwargs} {losses} != {ref}")


def _run_trainer(trainer, views, steps: int, prefetch: bool):
    trainer.reset(seed=0)
    # warmup x2 (see _run_naive) — the trace count still certifies a
    # single trace across every warmup + timed run of every strategy
    trainer.fit(views, steps=2, prefetch=False)
    t0 = time.perf_counter()
    trainer.fit(views, steps=steps, prefetch=prefetch)
    return time.perf_counter() - t0


def strategies(smoke: bool = False, out_json: str = "BENCH_strategies.json",
               P: int = 0, steps: int = 0):
    import jax
    import numpy as np

    from benchmarks.common import emit
    from repro.config import GNNConfig
    from repro.core.clustering import label_propagation_clusters
    from repro.core.engine import HybridParallelEngine
    from repro.core.partition import build_partitions
    from repro.core.strategies import (shard_view, shard_view_loop,
                                       strategy_views)
    from repro.core.trainer import Trainer
    from repro.graph import sbm_graph
    from repro.models import make_gnn
    from repro.optim import adam

    if smoke and out_json == "BENCH_strategies.json":
        out_json = "BENCH_strategies_smoke.json"   # don't clobber nightly

    # cap the worker group at the physical core count: fake host devices
    # beyond that time-slice the all_to_all rendezvous and the bench
    # measures scheduler noise instead of the pipeline
    P = P or max(1, min(4, len(jax.devices()), os.cpu_count() or 1))
    # hidden is kept small on purpose: host-side view preparation (khop
    # BFS, cluster masks, shard_view, device_put) is what this bench
    # isolates, and it is independent of the feature width
    if smoke:
        steps, nodes, hidden, repeats = steps or 3, 300, 16, 1
    else:
        steps, nodes, hidden, repeats = steps or 15, 800, 8, 9
    g = sbm_graph(num_nodes=nodes, num_classes=4, feature_dim=hidden,
                  p_in=0.02, p_out=0.002, seed=0).add_self_loops()
    clusters = label_propagation_clusters(
        g, max_cluster_size=max(64, nodes // 12), seed=0)
    sg = build_partitions(g, P)
    opt = adam(1e-2)

    # large target batches / halos so host-side view construction is a
    # realistic fraction of the step (the DistDGL regime this pipeline
    # is for), not a rounding error behind the device math
    def views_for(strategy, seed=0):
        return strategy_views(g, strategy, K=2, seed=seed,
                              batch_nodes=max(16, 3 * nodes // 8),
                              clusters=clusters, halo_hops=2,
                              clusters_per_batch=max(
                                  1, (int(clusters.max()) + 1) // 4))

    # -- contract lane (smoke): compiled-once + shard_view parity ------------
    for strategy in ("global", "mini", "cluster"):
        v = next(iter(views_for(strategy, seed=9)))
        a, b = shard_view(sg.plan, v), shard_view_loop(sg.plan, v)
        assert set(a) == set(b)
        for k in a:
            assert np.array_equal(a[k], b[k]), (
                f"vectorized shard_view diverges from loop: "
                f"{strategy}/{k}")
    emit("strategies/contract_shard_view", 0.0, "vectorized==loop")

    # -- host-side view construction: loop vs vectorized vs cached -----------
    view_build = _view_build_section(g, 2, clusters, smoke)

    # -- compact sampled-subgraph views vs dense masks at growing N ----------
    compact_views = _compact_views_section(smoke)

    # -- thread vs process view-construction pools (PR 10) -------------------
    prefetch_mode = _prefetch_mode_section(smoke)

    rows, summary = [], {}
    for backend in ("reference", "csc"):
        cfg = GNNConfig(model="gcn", num_layers=2, hidden_dim=hidden,
                        num_classes=4, feature_dim=hidden,
                        aggregate_backend=backend)
        engine = HybridParallelEngine(make_gnn(cfg), sg)
        trainer = Trainer(engine, opt, seed=0)
        naive_step = engine.make_train_step(opt)
        n_steps = steps
        runners = {
            "naive": lambda s: _run_naive(engine, naive_step, opt,
                                          views_for(s), n_steps),
            "trainer": lambda s: _run_trainer(trainer, views_for(s),
                                              n_steps, prefetch=False),
            "trainer_prefetch": lambda s: _run_trainer(
                trainer, views_for(s), n_steps, prefetch=True),
        }
        order = list(runners)
        for strategy in ("global", "mini", "cluster"):
            # interleave the variants, rotating the order each repeat, and
            # take the min wall per variant: slow machine drift (co-tenant
            # CPU, allocator pressure) then hits every variant at every
            # position instead of whichever happens to run last
            walls = {v: float("inf") for v in runners}
            for r in range(repeats):
                for v in order[r % 3:] + order[:r % 3]:
                    walls[v] = min(walls[v], runners[v](strategy))
            for variant, wall in walls.items():
                sps = n_steps / wall
                emit(f"strategies/{strategy}_{backend}_{variant}",
                     wall / n_steps * 1e6,
                     f"steps_per_sec={sps:.2f};P={P};N={g.num_nodes};"
                     f"E={g.num_edges}")
                rows.append({
                    "strategy": strategy, "backend": backend,
                    "variant": variant, "P": P, "steps": n_steps,
                    "steps_per_sec": round(sps, 3),
                    "ms_per_step": round(wall / n_steps * 1e3, 3),
                    "num_nodes": g.num_nodes, "num_edges": g.num_edges,
                    "hidden_dim": hidden,
                    "prefetch": variant == "trainer_prefetch",
                    "interpret_mode": jax.default_backend() != "tpu",
                })
            key = f"{strategy}/{backend}"
            summary[key] = {
                "naive_wall_s": round(walls["naive"], 4),
                "trainer_prefetch_wall_s": round(
                    walls["trainer_prefetch"], 4),
                "prefetch_speedup_vs_naive": round(
                    walls["naive"] / walls["trainer_prefetch"], 3),
                "prefetch_speedup_vs_no_prefetch": round(
                    walls["trainer"] / walls["trainer_prefetch"], 3),
            }
        if smoke and backend == "reference":
            # multi-stream determinism: same trajectory for any worker
            # count (the steps ride on the same compiled-once executable)
            _assert_multistream_determinism(trainer, views_for)
            emit("strategies/contract_multistream_determinism", 0.0,
                 "workers{1,4}==no-prefetch")
        # compiled-once across ALL strategies on one engine — the Trainer
        # contract the paper's flexible-strategy claim rides on
        trainer.assert_compiled_once()
        emit(f"strategies/contract_compiled_once_{backend}", 0.0,
             f"traces={trainer.trace_counts['train_step']}")

    naive_total = sum(v["naive_wall_s"] for v in summary.values())
    prefetch_total = sum(v["trainer_prefetch_wall_s"]
                         for v in summary.values())
    payload = {
        "bench": "strategies",
        "mode": "smoke" if smoke else "full",
        "rows": rows,
        "summary": summary,
        "view_build": view_build,
        "compact_views": compact_views,
        "prefetch_mode": prefetch_mode,
        # headline: total wall over all strategy x backend cells — the
        # per-cell margins for the cheap-host-prep cells sit near the
        # 2-core box's timing noise, the aggregate does not
        "naive_total_wall_s": round(naive_total, 4),
        "trainer_prefetch_total_wall_s": round(prefetch_total, 4),
        "prefetch_trainer_beats_naive": bool(prefetch_total < naive_total),
        "prefetch_trainer_speedup_vs_naive_total": round(
            naive_total / max(prefetch_total, 1e-9), 3),
        "note": ("wall-clock on CPU is interpret-mode emulation for the "
                 "csc backend (trajectory only); the compiled-once and "
                 "shard_view-parity contracts are hard-asserted"),
    }
    with open(out_json, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"wrote {out_json}", flush=True)
    return payload


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="CI mode: tiny shapes, Trainer contracts asserted")
    ap.add_argument("--devices", type=int, default=4,
                    help="fake host devices (worker-group size)")
    ap.add_argument("--steps", type=int, default=0)
    ap.add_argument("--out", default="BENCH_strategies.json")
    args = ap.parse_args(argv)
    # must happen before jax is first imported
    os.environ.setdefault(
        "XLA_FLAGS",
        f"--xla_force_host_platform_device_count={args.devices}")
    strategies(smoke=args.smoke, out_json=args.out, steps=args.steps)
    return 0


if __name__ == "__main__":
    sys.exit(main())
