"""Shared benchmark helpers: timing, CSV emission, tiny metrics."""
from __future__ import annotations

import time

import jax
import numpy as np

ROWS = []


def emit(name: str, us_per_call: float, derived: str = ""):
    row = f"{name},{us_per_call:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def time_call(fn, *args, warmup: int = 1, iters: int = 3) -> float:
    """Mean wall-clock microseconds per call (blocks on jax arrays)."""
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def binary_auc(labels: np.ndarray, scores: np.ndarray) -> float:
    """Rank-based AUC (no sklearn offline)."""
    order = np.argsort(scores)
    ranks = np.empty_like(order, dtype=np.float64)
    ranks[order] = np.arange(1, len(scores) + 1)
    pos = labels > 0
    n_pos = int(pos.sum())
    n_neg = len(labels) - n_pos
    if n_pos == 0 or n_neg == 0:
        return 0.5
    return float((ranks[pos].sum() - n_pos * (n_pos + 1) / 2)
                 / (n_pos * n_neg))


def f1_score(labels: np.ndarray, preds: np.ndarray) -> float:
    tp = float(np.sum((preds == 1) & (labels == 1)))
    fp = float(np.sum((preds == 1) & (labels == 0)))
    fn = float(np.sum((preds == 0) & (labels == 1)))
    if tp == 0:
        return 0.0
    p = tp / (tp + fp)
    r = tp / (tp + fn)
    return 2 * p * r / (p + r)
